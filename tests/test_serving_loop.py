"""Async pipelined serving loop: double-buffer consistency, donation
byte-identity, and sync-vs-overlapped output equality.

The overlap is only legal because it is UNOBSERVABLE: every test here pins
some facet of that — a query racing a donated in-place ingest must see
exactly the pre- or post-tick snapshot (never a torn mix), donated jits
must produce byte-identical outputs to their copying twins, and the whole
loop (and the scenario engine under ``async_loop=True``) must replay
bit-identically against the synchronous schedule.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.knobs import Knobs
from repro.core.query import Query, execute_query
from repro.core.store import (SnapshotStore, copy_store, synthetic_store)
from repro.serving.loadgen import LoadGenerator, LoadSpec
from repro.serving.loop import (IngestStream, ServingLoop, apply_delta,
                                _apply_delta_donated, _apply_delta2_donated)
from repro.server.fleet import FleetServer
from repro.server.zones import ZoneGrid

E, P, CAP, NLIVE = 32, 16, 128, 96

KN = Knobs(server_capacity=CAP, client_capacity=64,
           max_object_points_server=P, max_object_points_client=8,
           min_obs_before_sync=1)


def _store(seed=1):
    return synthetic_store(NLIVE, CAP, E, P, seed=seed)


def _stream(n_ticks=6, seed=3, **kw):
    kw.setdefault("churn", 24)
    return IngestStream(n_ticks=n_ticks, n_live=NLIVE, embed_dim=E,
                        max_points=P, seed=seed, **kw)


def _oracle_topk(store, q, k):
    """Numpy flat-sweep oracle over a host snapshot: active slots only,
    cosine score, descending."""
    act = np.asarray(store.active)
    sim = np.asarray(store.embed) @ np.asarray(q)
    sim[~act] = -np.inf
    order = np.argsort(-sim)[:k]
    return np.asarray(store.ids)[order], sim[order]


def _stores_equal(a, b):
    return all(
        (x is None and y is None)
        or np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# double-buffer consistency: a query racing the donated ingest sees a
# consistent snapshot
# ---------------------------------------------------------------------------
def test_mid_ingest_query_is_exactly_pre_tick_snapshot():
    snap = SnapshotStore.of(_store())
    stream = _stream()
    d = stream.delta_at(0)
    pre_host = jax.tree.map(np.asarray, snap.front)       # pre-tick oracle
    post = apply_delta(copy_store(snap.front), d)         # post-tick oracle
    post_host = jax.tree.map(np.asarray, post)

    # aim the query at a slot this delta re-embeds, so pre and post top-k
    # actually differ — a torn read could not pass both arms below
    slot = int(np.asarray(d.slots)[np.argmin(np.asarray(d.tomb))])
    q = np.asarray(d.embed)[np.argmin(np.asarray(d.tomb))]
    pre_ids, pre_sc = _oracle_topk(pre_host, q, 5)
    post_ids, post_sc = _oracle_topk(post_host, q, 5)
    assert int(post_host.ids[slot]) == int(post_ids[0])
    assert not np.array_equal(pre_sc, post_sc)

    # in-flight donated ingest: the back buffer is being overwritten NOW
    back = snap.take_back()
    new = _apply_delta_donated(back, d)
    mid = execute_query(snap.front, Query(embed=jnp.asarray(q), k=5))
    assert np.array_equal(np.asarray(mid.oids), pre_ids)
    np.testing.assert_allclose(np.asarray(mid.scores), pre_sc, atol=1e-5)

    snap.publish(new, pending=d)
    after = execute_query(snap.front, Query(embed=jnp.asarray(q), k=5))
    assert np.array_equal(np.asarray(after.oids), post_ids)
    np.testing.assert_allclose(np.asarray(after.scores), post_sc,
                               atol=1e-5)


def test_tombstone_during_query_pre_or_post_never_mixed():
    snap = SnapshotStore.of(_store())
    # hand-built delta: tombstone the store's best match for q
    q = np.asarray(snap.front.embed[7])
    pre_host = jax.tree.map(np.asarray, snap.front)
    pre_ids, _ = _oracle_topk(pre_host, q, 3)
    victim_slot = 7
    assert int(pre_host.ids[victim_slot]) == int(pre_ids[0])
    U = _stream().delta_at(0).slots.shape[0]
    d = _stream().delta_at(0)._replace(
        slots=jnp.zeros((U,), jnp.int32).at[0].set(victim_slot),
        tomb=jnp.zeros((U,), bool).at[0].set(True),
        valid=jnp.zeros((U,), bool).at[0].set(True))

    back = snap.take_back()
    new = _apply_delta_donated(back, d)
    mid = execute_query(snap.front, Query(embed=jnp.asarray(q), k=3))
    # mid-removal: the victim is still the top hit of the published snap
    assert int(np.asarray(mid.oids)[0]) == int(pre_ids[0])

    snap.publish(new, pending=d)
    post = execute_query(snap.front, Query(embed=jnp.asarray(q), k=3))
    post_host = jax.tree.map(np.asarray, snap.front)
    post_ids, _ = _oracle_topk(post_host, q, 3)
    assert int(pre_ids[0]) not in np.asarray(post.oids)
    assert np.array_equal(np.asarray(post.oids), post_ids)


def test_snapshot_store_protocol_guards():
    snap = SnapshotStore.of(_store())
    b = snap.take_back()
    with pytest.raises(AssertionError):
        snap.take_back()
    snap.publish(b)
    assert snap.version == 1
    with pytest.raises(AssertionError):
        snap.publish(b)


# ---------------------------------------------------------------------------
# donation byte-identity: donated jits are scheduling-only changes
# ---------------------------------------------------------------------------
def test_donated_ingest_chain_matches_copying_chain():
    stream = _stream(n_ticks=5)
    ref = _store()
    for t in range(5):
        ref = apply_delta(ref, stream.delta_at(t))
    ref = jax.tree.map(np.asarray, ref)

    # double-buffered donated chain with the pending-delta catch-up: the
    # two-tick-old buffer replays (pending, current) each tick
    snap = SnapshotStore.of(_store())
    for t in range(5):
        d = stream.delta_at(t)
        back = snap.take_back()
        new = _apply_delta_donated(back, d) if snap.pending is None \
            else _apply_delta2_donated(back, snap.pending, d)
        snap.publish(new, pending=d)
    assert _stores_equal(ref, jax.tree.map(np.asarray, snap.front))


def test_collect_donation_byte_identity():
    from repro.server.session import SessionManager
    store = _store()
    sub = np.ones((4,), bool)
    a = SessionManager(knobs=KN, n_clients=4, capacity=CAP, budget=16,
                      subscribed=sub.copy())
    b = SessionManager(knobs=KN, n_clients=4, capacity=CAP, budget=16,
                      donate=True, subscribed=sub.copy())
    for tick in range(3):
        pa = a.collect(store)
        pb = b.collect_finish(b.collect_start(store))
        assert np.array_equal(pa.nbytes, pb.nbytes)
        assert np.array_equal(pa.counts, pb.counts)
        assert np.array_equal(np.asarray(pa.batch.oid),
                              np.asarray(pb.batch.oid))
        assert np.array_equal(np.asarray(pa.batch.valid),
                              np.asarray(pb.batch.valid))
    assert np.array_equal(np.asarray(a.sync.synced_version),
                          np.asarray(b.sync.synced_version))


def test_device_client_donated_ingest_identity():
    from repro.core.runtime import CloudService, DeviceClient
    from repro.core import MappingServer
    from repro.data.scenes import make_scene, scene_stream
    from repro.perception.embedder import OracleEmbedder
    kn = Knobs(server_capacity=CAP, client_capacity=64,
               max_object_points_server=64, max_object_points_client=16,
               max_detections_per_frame=16, min_obs_before_sync=1)
    scene = make_scene(n_objects=10, seed=3)
    classes = {o.oid: o.class_id for o in scene.objects}
    srv = MappingServer(knobs=kn, embedder=OracleEmbedder(embed_dim=E),
                        mode="semanticxr")
    key = jax.random.key(0)
    for i, fr in enumerate(scene_stream(scene, n_frames=12,
                                        keyframe_interval=4, h=60, w=80)):
        srv.process_frame(fr, classes, jax.random.fold_in(key, i))

    out = []
    for donate in (False, True):
        cloud = CloudService(knobs=kn, store_ref=srv)
        dev = DeviceClient(knobs=kn, embed_dim=E, donate=donate)
        pkt = cloud.update_tick(network_up=True)
        dev.ingest(pkt, user_pos=jnp.zeros(3))
        out.append(jax.tree.map(np.asarray, dev.local))
    assert _stores_equal(out[0], out[1])


def test_mapping_server_donated_ingest_identity():
    from repro.core import MappingServer
    from repro.data.scenes import make_scene, scene_stream
    from repro.perception.embedder import OracleEmbedder
    kn = Knobs(server_capacity=CAP, client_capacity=64,
               max_object_points_server=64, max_object_points_client=16,
               max_detections_per_frame=16, min_obs_before_sync=1)
    scene = make_scene(n_objects=8, seed=5)
    classes = {o.oid: o.class_id for o in scene.objects}
    stores = []
    for donate in (False, True):
        srv = MappingServer(knobs=kn, embedder=OracleEmbedder(embed_dim=E),
                            mode="semanticxr", donate=donate)
        key = jax.random.key(0)
        for i, fr in enumerate(scene_stream(scene, n_frames=10,
                                            keyframe_interval=4,
                                            h=60, w=80)):
            srv.process_frame(fr, classes, jax.random.fold_in(key, i))
        stores.append(jax.tree.map(np.asarray, srv.store))
    assert _stores_equal(stores[0], stores[1])


# ---------------------------------------------------------------------------
# whole-loop equality: overlapped schedule is unobservable end to end
# ---------------------------------------------------------------------------
def _loop(overlap, n_ticks=10, C=6):
    store = _store()
    srv = FleetServer(knobs=KN, embed_dim=E, n_clients=C,
                      grid=ZoneGrid.for_room(16.0, 2, 2), budget=16,
                      donate=overlap)
    lg = LoadGenerator(LoadSpec(n_clients=C, n_ticks=n_ticks, base_hz=3.0,
                                burst_hz=30.0, burst_prob=0.1),
                       embed_dim=E)
    ing = _stream(n_ticks=n_ticks)
    snap = SnapshotStore.of(store) if overlap \
        else SnapshotStore(front=store)
    for c in range(C):
        srv.join(c, lg.pose_at(c, 0), 6.0)
    loop = ServingLoop(server=srv, store=snap, ingest=ing, loadgen=lg,
                       overlap=overlap, batch_size=8,
                       max_batches_per_tick=2)
    stats = loop.run(n_ticks)
    return loop, stats


def test_serving_loop_sync_vs_overlapped_byte_identical():
    a, sa = _loop(False)
    b, sb = _loop(True)
    assert sa["n_queries_served"] == sb["n_queries_served"] > 0
    assert sa["sent_bytes_total"] == sb["sent_bytes_total"] > 0
    assert set(a.results) == set(b.results)
    for rid in a.results:
        assert np.array_equal(a.results[rid].oids, b.results[rid].oids)
        assert np.array_equal(a.results[rid].scores, b.results[rid].scores)
    assert _stores_equal(jax.tree.map(np.asarray, a.store.front),
                         jax.tree.map(np.asarray, b.store.front))


def test_fleet_tick_overlap_byte_identity():
    """server.tick(overlap=True) must emit byte-identical packets to the
    sequential per-zone path, across refreshes and pose churn."""
    def run(overlap):
        rng = np.random.default_rng(0)
        store = _store()
        srv = FleetServer(knobs=KN, embed_dim=E, n_clients=5,
                          grid=ZoneGrid.for_room(16.0, 2, 2), budget=16,
                          donate=overlap)
        for c in range(5):
            srv.join(c, rng.uniform(-6, 6, 3).astype(np.float32), 7.0)
        stream = _stream(n_ticks=4, seed=9)
        out = []
        deliverable = np.ones((5,), bool)
        for t in range(4):
            store = apply_delta(store, stream.delta_at(t))
            srv.refresh(store)
            for z, pkt in srv.tick(deliverable, tick=t, overlap=overlap):
                out.append((z, np.asarray(pkt.nbytes).copy(),
                            np.asarray(pkt.batch.oid).copy(),
                            np.asarray(pkt.seqs).copy()))
        return out

    seq, ovl = run(False), run(True)
    assert len(seq) == len(ovl) > 0
    for (za, na, oa, sa), (zb, nb, ob, sb) in zip(seq, ovl):
        assert za == zb
        assert np.array_equal(na, nb)
        assert np.array_equal(oa, ob)
        assert np.array_equal(sa, sb)


def test_engine_async_loop_replay_bit_identical():
    from repro.sim import churn_scenario, run_scenario
    sc = churn_scenario(seed=11, n_objects=12, n_ticks=12, n_clients=2,
                        remove_frac=0.25, drain_ticks=4)
    a = run_scenario(sc)
    b = run_scenario(sc, async_loop=True)
    assert a.equals(b), f"drift in fields: {a.diff(b)}"


# ---------------------------------------------------------------------------
# load generator: seeded, open-loop, deterministic
# ---------------------------------------------------------------------------
def test_loadgen_deterministic_and_open_loop():
    spec = LoadSpec(n_clients=16, n_ticks=40, base_hz=1.0, burst_hz=20.0,
                    burst_prob=0.05, seed=4)
    a, b = LoadGenerator(spec, embed_dim=E), LoadGenerator(spec,
                                                           embed_dim=E)
    assert a.n_arrivals == b.n_arrivals > 0
    for ta, tb in zip(a.arrivals, b.arrivals):
        assert len(ta) == len(tb)
        for (ca, qa), (cb, qb) in zip(ta, tb):
            assert ca == cb
            assert np.array_equal(np.asarray(qa.embed),
                                  np.asarray(qb.embed))
            assert np.array_equal(np.asarray(qa.near[0]),
                                  np.asarray(qb.near[0]))
    # bursty: some tick carries >1 arrival; open loop: schedule exists
    # regardless of any server serving it
    assert max(len(t) for t in a.arrivals) > 1
    # poses follow the cadence and the parametric track
    p0 = a.poses(0)
    assert p0.shape == (16, 3)
    np.testing.assert_allclose(p0[3], a.pose_at(3, 0), atol=1e-6)


def test_batched_pose_update_matches_per_client_path():
    """overlaps_batch == per-client overlaps, and FleetServer.set_poses
    leaves identical session state to C set_client_pose calls."""
    grid = ZoneGrid.for_room(16.0, 3, 2)
    rng = np.random.default_rng(2)
    poses = rng.uniform(-10, 10, size=(32, 3)).astype(np.float32)
    batch = grid.overlaps_batch(poses, 5.0)
    for c in range(32):
        assert np.array_equal(batch[c], grid.overlaps(poses[c], 5.0))

    def mk():
        srv = FleetServer(knobs=KN, embed_dim=E, n_clients=6,
                          grid=ZoneGrid.for_room(16.0, 2, 2), budget=16)
        for c in range(6):
            srv.join(c, poses[c], 6.0)
        return srv
    a, b = mk(), mk()
    for t in range(3):
        step = poses[t * 6:(t + 1) * 6] * (0.5 + 0.2 * t)
        for c in range(6):
            a.set_client_pose(c, step[c], 6.0)
        b.set_poses(step, 6.0)
        assert np.array_equal(a.subscribed, b.subscribed)
        for sa, sb in zip(a.sessions, b.sessions):
            assert sa.dirty == sb.dirty
            assert np.array_equal(sa.subscribed, sb.subscribed)
            assert np.array_equal(sa.user_pos, sb.user_pos)
            assert np.array_equal(np.asarray(sa.sync.synced_version),
                                  np.asarray(sb.sync.synced_version))
            assert np.array_equal(sa.next_seq, sb.next_seq)


def test_loadgen_latency_accounting():
    lg = LoadGenerator(LoadSpec(n_clients=2, n_ticks=4, seed=0),
                       embed_dim=E)
    lg.note_submit(0, 1.0)
    lg.note_served(0, 1.010)
    lg.note_resolved(0, 1.025)
    assert lg.wait_ms == [pytest.approx(10.0)]
    assert lg.e2e_ms == [pytest.approx(25.0)]
    rep = lg.record("test")
    assert rep["e2e_ms"]["p99"] == pytest.approx(25.0)


def test_donate_auto_policy_resolution():
    """donate=None resolves through the one backend-aware policy helper
    (kernels.ops.donate_default): OFF on CPU — where a donated dispatch
    blocks on the donated buffer's producer and serializes the overlap —
    ON for TPU/GPU.  Explicit True/False are untouched."""
    from repro.kernels.ops import donate_default
    from repro.server.session import SessionManager
    want = donate_default()
    assert want == (jax.default_backend() not in ("cpu",))
    sm = SessionManager(knobs=KN, n_clients=2, capacity=CAP, donate=None)
    assert sm.donate == want
    assert SessionManager(knobs=KN, n_clients=2, capacity=CAP,
                          donate=True).donate is True
    assert SessionManager(knobs=KN, n_clients=2, capacity=CAP,
                          donate=False).donate is False
    # FleetServer passes the auto policy through to every zone session
    srv = FleetServer(knobs=KN, embed_dim=E, n_clients=2,
                      grid=ZoneGrid.for_room(8.0, 2, 1), donate=None)
    assert all(s.donate == want for s in srv.sessions)
    # the engine's overlapped mode asks for auto (bug was donate=True
    # unconditionally: the async loop lost its overlap win on CPU)
    from repro.sim.engine import ScenarioEngine
    from repro.sim.scenario import (ClientSpec, GridSpec, NetTrace,
                                    PoseTrack, Scenario)
    sc = Scenario(seed=0, n_ticks=1, embed_dim=E, knobs=KN,
                  grid=GridSpec(room=8.0, nx=1, nz=1),
                  clients=(ClientSpec(cid=0, net=NetTrace(), 
                                      track=PoseTrack()),))
    eng = ScenarioEngine(sc, async_loop=True)
    assert all(s.donate == want for s in eng.server.sessions)
    eng2 = ScenarioEngine(sc, async_loop=False)
    assert all(s.donate is False for s in eng2.server.sessions)


def test_serving_loop_sharded_session_tier_byte_identity():
    """The sharded session tier threads through the serving loop's
    tick_start/tick_finish schedule unchanged: same per-tick sent bytes and
    identical fleet sync state as the single-device tier, in both the
    fenced and overlapped schedules."""
    def run(shards, overlap):
        store = _store()
        srv = FleetServer(knobs=KN, embed_dim=E, n_clients=6,
                          grid=ZoneGrid.for_room(16.0, 2, 2), budget=16,
                          n_session_shards=shards, donate=None)
        rng = np.random.default_rng(5)
        for c in range(6):
            srv.join(c, rng.uniform(-6, 6, 3).astype(np.float32), 6.0)
        snap = SnapshotStore.of(store) if overlap \
            else SnapshotStore(front=store)
        loop = ServingLoop(server=srv, store=snap, ingest=_stream(seed=11),
                           overlap=overlap)
        loop.run(6)
        return loop.sent_bytes, srv

    for overlap in (False, True):
        s1, srv1 = run(1, overlap)
        s3, srv3 = run(3, overlap)
        assert s1 == s3, (overlap, s1, s3)
        # per-zone sync state identical after reassembly
        for z, (a, b) in enumerate(zip(srv1.sessions, srv3.sessions)):
            va = np.asarray(a.sync.synced_version)
            vb = np.zeros_like(va)
            for s, part in enumerate(b.parts):
                if part is not None:
                    vb[b.roster.members[s]] = np.asarray(
                        part.sync.synced_version)
            np.testing.assert_array_equal(va, vb, err_msg=f"zone {z}")
