"""NetworkModel in-flight semantics: a transfer whose window straddles an
outage start is delayed (stalls through the outage), never delivered at
pre-outage latency.  Regression for the seed behavior where delivery only
checked link state at send time."""
import numpy as np
import jax.numpy as jnp

from repro.core.knobs import Knobs
from repro.core.runtime import ClientSession, DeviceClient, NetworkModel
from repro.core.store import init_store
from repro.core.updates import collect_updates, init_sync

KN = Knobs(server_capacity=32, client_capacity=32,
           max_object_points_server=32, max_object_points_client=16,
           min_obs_before_sync=1)


def _net(**kw):
    base = dict(rtt_ms=100.0, bandwidth_mbps=0.008, outages=((4.0, 8.0),))
    base.update(kw)
    return NetworkModel(**base)


def test_clear_window_delivers_at_nominal_latency():
    net = _net()
    # 1 kB at 8 kbps = 1 s + 0.1 s rtt; window [1.0, 2.1] clears the outage
    assert np.isclose(net.delivery_time(1.0, 1000), 2.1)


def test_straddling_transfer_stalls_through_outage():
    net = _net()
    # sent at t=3.5: 0.5 s progresses before the outage at 4.0, the
    # remaining 0.6 s resumes at 8.0 -> delivered 8.6, NOT 4.6
    at = net.delivery_time(3.5, 1000)
    assert np.isclose(at, 8.6), at
    assert at > 8.0


def test_send_during_outage_is_not_in_flight():
    assert _net().delivery_time(5.0, 1000) is None


def test_back_to_back_outages_accumulate():
    net = _net(outages=((4.0, 8.0), (8.5, 10.0)))
    # sent 3.5: 0.5 s before first outage, 0.5 s in (8.0, 8.5), remaining
    # 0.1 s after 10.0 -> 10.1
    assert np.isclose(net.delivery_time(3.5, 1000), 10.1)


def test_nested_outage_window_is_not_double_counted():
    net = _net(outages=((4.0, 8.0), (5.0, 6.0)))
    # (5, 6) lies entirely inside (4, 8): the stall is still just [4, 8].
    # sent 3.5 -> 0.5 s before the outage, 0.6 s after 8.0 -> 8.6
    assert np.isclose(net.delivery_time(3.5, 1000), 8.6)
    # and a send inside the nested window reports link-down, not a crash
    assert net.delivery_time(5.5, 1000) is None


def test_three_window_walk_accumulates_each_gap():
    net = _net(outages=((4.0, 8.0), (8.0, 10.0), (10.5, 11.0)))
    # sent 3.5: 0.5 s progress, stall 4->8 abuts 8->10 (resume at 10.0),
    # 0.5 s progress in (10.0, 10.5), final 0.1 s after 11.0 -> 11.1
    assert np.isclose(net.delivery_time(3.5, 1000), 11.1)
    # the same windows, progress starting between them: sent 10.0 needs
    # 0.555 s; 0.5 s fits before (10.5, 11.0), remainder lands 11.055
    assert np.isclose(net.delivery_time(10.0, 455), 11.055)


def test_delivery_is_fifo_per_link():
    """A packet sent while an older one is still in flight queues behind it
    — a newer-version update can never be overtaken and then overwritten
    when the stale packet matures."""
    store = init_store(KN.server_capacity, 8, KN.max_object_points_server)
    store = store._replace(
        ids=store.ids.at[0].set(7), active=store.active.at[0].set(True),
        embed=store.embed.at[0].set(jnp.ones(8) / np.sqrt(8.0)),
        n_points=store.n_points.at[0].set(4),
        obs_count=store.obs_count.at[0].set(3),
        version=store.version.at[0].set(1))
    sync = init_sync(KN.server_capacity)
    pkt_v1, sync = collect_updates(store, sync, KN, tick=0)
    store = store._replace(version=store.version.at[0].set(2))
    pkt_v2, _ = collect_updates(store, sync, KN, tick=1)
    assert pkt_v1.count == 1 and pkt_v2.count == 1

    net = _net(rtt_ms=0.0, bandwidth_mbps=pkt_v1.nbytes * 8 / 1e6)  # 1 s xfer
    sess = ClientSession(dev=DeviceClient(knobs=KN, embed_dim=8), net=net,
                         knobs=KN, dt=1.0)
    sess.step(3.5, pkt_v1)            # straddles the outage: in flight @8.5
    sess.step(8.0, pkt_v2)            # link up again, but v1 still in
    assert sess.delivered == 0        # flight: v2 queues behind it (FIFO)
    assert len(sess.pending) == 2
    sess.step(12.0)                   # both matured, in send order
    assert sess.delivered == 2
    assert int(sess.dev.local.version[0]) == 2   # newest version wins


def test_retransmit_walks_adjacent_outages():
    """Sending inside an outage that abuts another must not crash; the
    retransmit lands after the last adjacent window."""
    net = _net(outages=((4.0, 8.0), (8.0, 10.0)))
    sess = ClientSession(dev=DeviceClient(knobs=KN, embed_dim=8), net=net,
                         knobs=KN, dt=1.0)

    class _Pkt:            # stand-in with the UpdatePacket delivery fields
        count, nbytes, batch, tick = 1, 100, None, 0
    sess.step(5.0, _Pkt())            # mid-outage send: queued, no TypeError
    assert sess.delayed == 1 and sess.pending[0][0] >= 10.0


def test_client_session_defers_straddled_packet():
    """The shared per-tick step holds a straddled packet in flight and
    ingests it only after the outage ends."""
    store = init_store(KN.server_capacity, 8, KN.max_object_points_server)
    store = store._replace(
        ids=store.ids.at[0].set(7), active=store.active.at[0].set(True),
        embed=store.embed.at[0].set(jnp.ones(8) / np.sqrt(8.0)),
        n_points=store.n_points.at[0].set(4),
        obs_count=store.obs_count.at[0].set(3),
        version=store.version.at[0].set(1))
    pkt, _ = collect_updates(store, init_sync(KN.server_capacity), KN,
                             tick=0)
    assert pkt.count == 1
    net = _net(rtt_ms=0.0, bandwidth_mbps=pkt.nbytes * 8 / 1e6)  # 1 s xfer
    sess = ClientSession(dev=DeviceClient(knobs=KN, embed_dim=8), net=net,
                         knobs=KN, dt=1.0)
    sess.step(3.5, pkt)                       # straddles the 4.0 outage
    assert sess.delayed == 1 and sess.down_bytes == 0
    assert int(sess.dev.local.active.sum()) == 0
    sess.step(5.0)                            # still down: nothing arrives
    assert sess.down_bytes == 0
    sess.step(9.0)                            # past 8.5 delivery: ingested
    assert sess.down_bytes == pkt.nbytes
    assert int(sess.dev.local.active.sum()) == 1
