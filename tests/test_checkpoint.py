"""Checkpoint durability + elastic re-shard restore."""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.models.api import model_api


def test_roundtrip_bf16(tmp_path):
    cfg = get_config("semanticxr-captioner-110m-smoke")
    api = model_api(cfg)
    params = api.init(jax.random.key(0))
    ckpt.save(tmp_path, 7, params)
    assert ckpt.latest_step(tmp_path) == 7
    back = ckpt.restore(tmp_path, 7, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_retention_and_atomicity(tmp_path):
    cfg = get_config("semanticxr-captioner-110m-smoke")
    api = model_api(cfg)
    params = api.init(jax.random.key(0))
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, params, keep=3)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4, 5]
    assert ckpt.latest_step(tmp_path) == 5


def test_elastic_reshard_restore(tmp_path):
    """Checkpoints are logical tensors: restore onto a different mesh shape
    (here: unsharded save -> 1x1 mesh with explicit shardings), the rescale
    path for node-count changes."""
    cfg = get_config("yi-9b-smoke")
    api = model_api(cfg)
    params = api.init(jax.random.key(1))
    ckpt.save(tmp_path, 1, params)

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    from repro.distributed import sharding as sh
    pspecs = sh.param_pspecs(cfg, api.param_specs(), mesh)
    shardings = jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                             is_leaf=lambda x: isinstance(x, P))
    back = ckpt.restore(tmp_path, 1, params, shardings=shardings)
    a = jax.tree.leaves(params)[0]
    b = jax.tree.leaves(back)[0]
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
    assert jax.tree.leaves(back)[0].sharding is not None
